"""End-to-end driver #1 (the paper's kind): full CP-ALS decomposition of a
large-ish sparse tensor with the heterogeneous (dense-MXU + sparse) engine
and the distributed engine, with convergence tracking.

  PYTHONPATH=src python examples/decompose_tensor.py [--tensor amazon]
      [--rank 10] [--iters 5] [--engine hetero|chunked|fixed|distributed]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cp_als, decide_partition, table1_tensor
from repro.core.chunking import chunk_tensor
from repro.core.distributed import DistributedMTTKRP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tensor", default="amazon")
    ap.add_argument("--rank", type=int, default=10)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--engine", default="hetero")
    args = ap.parse_args()

    st = table1_tensor(args.tensor)
    print(f"[decompose] {args.tensor}: dims={st.shape} nnz={st.nnz}")
    plan = decide_partition(st, args.rank, mem_bytes=256 * 1024,
                            rank_axis=args.rank)
    print(f"[decompose] plan: chunks={plan.chunk_shape} cap={plan.capacity}")

    if args.engine == "distributed":
        # rank partitioning on `model`, chunk/task partitioning on `data` —
        # on this host the mesh is however many CPU devices exist (run under
        # XLA_FLAGS=--xla_force_host_platform_device_count=8 to see sharding).
        n = len(jax.devices())
        mesh = jax.make_mesh(
            (max(n // 2, 1), min(n, 2)), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        ct = chunk_tensor(st, plan.chunk_shape, plan.capacity)
        dmt = DistributedMTTKRP(mesh, ct, args.rank, reduce="psum")
        engine = lambda f, m: jnp.asarray(dmt(f, m))[: st.shape[m]]
    else:
        engine = args.engine

    t0 = time.time()
    res = cp_als(st, args.rank, n_iters=args.iters, engine=engine, seed=0,
                 chunk_shape=plan.chunk_shape, capacity=plan.capacity
                 if args.engine != "distributed" else None)
    print(f"[decompose] engine={args.engine} iters={args.iters} "
          f"wall={time.time()-t0:.1f}s")
    for i, (f, d) in enumerate(zip(res.fit_history, res.diff_history)):
        print(f"  iter {i+1}: fit={f:+.4f} avg|X-X̂|={d:.5f}")


if __name__ == "__main__":
    main()
