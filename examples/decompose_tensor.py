"""End-to-end driver #1 (the paper's kind): full CP-ALS decomposition of a
large-ish sparse tensor through the backend registry — heterogeneous
(dense-MXU + sparse), distributed (shard_map mesh), or the empirical
autotuner — with convergence tracking.

  PYTHONPATH=src python examples/decompose_tensor.py [--tensor amazon]
      [--rank 10] [--iters 5]
      [--engine auto|hetero|chunked|fixed|distributed|ref|alto|csf|pallas]
      [--store [PATH]] [--max-probes K]

`--store` persists autotune winners (default ~/.cache/repro/autotune.json,
or $REPRO_AUTOTUNE_CACHE): re-running the same decomposition skips the
probe phase.  `--max-probes` caps a cold start to the cost-model prior's
top-K candidates.

The distributed engine shards over however many devices this host exposes;
run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to see real
sharding on a CPU host.
"""
import argparse
import time

from repro.core import cp_als, decide_partition, table1_tensor
from repro.engine import (TunePolicy, backend_table, build_engine,
                          registered_backends)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tensor", default="amazon")
    ap.add_argument("--rank", type=int, default=10)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", *sorted(registered_backends())])
    ap.add_argument("--store", nargs="?", const=True, default=None,
                    help="persist autotune winners (optional PATH; bare flag "
                         "uses the default store)")
    ap.add_argument("--max-probes", type=int, default=None,
                    help="cold-start probe budget (prior's top-K)")
    ap.add_argument("--list-backends", action="store_true")
    args = ap.parse_args()

    if args.list_backends:
        print(backend_table(docs_base=None))  # terminal output: no link noise
        return

    st = table1_tensor(args.tensor)
    print(f"[decompose] {args.tensor}: dims={st.shape} nnz={st.nnz}")
    plan = decide_partition(st, args.rank, mem_bytes=256 * 1024,
                            rank_axis=args.rank)
    print(f"[decompose] plan: chunks={plan.chunk_shape} cap={plan.capacity}")

    t0 = time.time()
    engine = build_engine(st, args.engine, args.rank,
                          chunk_shape=plan.chunk_shape, capacity=plan.capacity,
                          tune=TunePolicy(store=args.store,
                                          max_probes=args.max_probes))
    if engine.report is not None:
        print(engine.report.summary())
        print(f"[decompose] tuning: source={engine.report.source} "
              f"probes={engine.report.n_probes} ({time.time()-t0:.2f}s)")

    t0 = time.time()
    res = cp_als(st, args.rank, n_iters=args.iters, engine=engine, seed=0)
    print(f"[decompose] engine={engine.name} iters={args.iters} "
          f"wall={time.time()-t0:.1f}s")
    for i, (f, d) in enumerate(zip(res.fit_history, res.diff_history, strict=True)):
        print(f"  iter {i+1}: fit={f:+.4f} avg|X-X̂|={d:.5f}")


if __name__ == "__main__":
    main()
