"""End-to-end driver #3: serve a small LM with batched requests — prefill
(teacher-forced) + batched greedy decode against ring-buffer KV caches.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --batch 4
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import generate, make_ctx
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    lm = LM(cfg)
    ctx = make_ctx(make_local_mesh(), seq_sharded=False)
    params, _ = lm.init(jax.random.key(0))
    prompts = jnp.asarray(
        SyntheticTokens(cfg.vocab, args.prompt_len, args.batch).batch(0))
    t0 = time.time()
    toks = generate(lm, params, ctx, prompts, args.gen)
    dt = time.time() - t0
    print(f"[serve_lm] {args.arch}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} -> {toks.shape} "
          f"in {dt:.2f}s ({args.batch*args.gen/dt:.1f} tok/s)")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
