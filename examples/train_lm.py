"""End-to-end driver #2: train a ~100M-param LM for a few hundred steps with
checkpoint/restart fault tolerance and CP gradient compression.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 200 --fail-at 80
  PYTHONPATH=src python examples/train_lm.py --steps 200   # resumes @80

The ~100M config is the xlstm-350m family reduced to ~100M params
(d_model=512, 12 layers) — trained on the synthetic token stream; the loss
must drop visibly within a few hundred steps.
"""
import argparse
import dataclasses
import subprocess
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.models import LayerSpec, ModelConfig


def hundred_m_config(d_model=512, n_layers=12) -> ModelConfig:
    _M = LayerSpec(mixer="mlstm", mlp="none")
    _S = LayerSpec(mixer="slstm", mlp="none")
    return ModelConfig(
        name="xlstm-100m", family="ssm",
        n_layers=n_layers, d_model=d_model, n_heads=4, n_kv_heads=4,
        head_dim=d_model // 4, d_ff=0, vocab=50304, rope=False,
        pattern=(_M, _M, _M, _S), tie_embeddings=True,
        supports_long_context=True, mlstm_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--d-model", type=int, default=256,
                    help="512 = the full ~100M config (slow on 1 CPU core); "
                         "256 = CI-sized same-family model")
    ap.add_argument("--n-layers", type=int, default=8)
    args = ap.parse_args()

    # monkey-patch the trainer's config resolution with the 100M-family model
    import repro.launch.train as t
    orig = t.get_smoke_config
    t.get_smoke_config = lambda name: hundred_m_config(args.d_model,
                                                       args.n_layers)
    try:
        argv = ["--arch", "xlstm-100m", "--smoke",
                "--steps", str(args.steps),
                "--seq-len", "64", "--global-batch", "8",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20",
                "--log-every", "10"]
        if args.fail_at:
            argv += ["--simulate-failure-at", str(args.fail_at)]
        t.main(argv)
    finally:
        t.get_smoke_config = orig


if __name__ == "__main__":
    main()
