"""Quickstart: decompose a sparse tensor with PRISM on this machine.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end in miniature: build a sparse tensor, let the
Fig. 5 decider pick a partition, run CP-ALS with the PRISM chunked engine
(float), the fixed-point engine (paper Alg. 2), and the Pallas TPU kernel
(interpret mode on CPU), and compare convergence.
"""
import jax
import numpy as np

from repro.core import (cp_als, decide_partition, random_tensor)

def main():
    # A Nell-2-like synthetic tensor (see benchmarks/table1.py for the set).
    st = random_tensor((605, 460, 1440), nnz=50_000, seed=0)
    print(f"tensor: dims={st.shape} nnz={st.nnz} density={st.density:.2e}")

    rank = 10
    plan = decide_partition(st, rank, mem_bytes=256 * 1024, rank_axis=rank)
    print(f"partition plan (Fig. 5): chunk_shape={plan.chunk_shape} "
          f"capacity={plan.capacity} rank_block={plan.rank_block} "
          f"kernel_iterations={plan.kernel_iterations}")

    for engine, kw in [
        ("ref", {}),
        ("chunked", dict(chunk_shape=plan.chunk_shape, capacity=plan.capacity)),
        ("fixed", dict(chunk_shape=plan.chunk_shape, capacity=plan.capacity,
                       fixed_preset="int7")),
        ("pallas", dict(chunk_shape=plan.chunk_shape,
                        capacity=min(plan.capacity, 128))),
    ]:
        res = cp_als(st, rank, n_iters=3, engine=engine, seed=0, **kw)
        print(f"engine={engine:8s} fit={res.fit_history[-1]:+.4f} "
              f"avg|X-X̂|={res.diff_history[-1]:.5f} "
              f"t/iter={np.mean(res.iter_times):.2f}s")


if __name__ == "__main__":
    main()
