"""Offline design-space sweep CLI (docs/tuning-pipeline.md#sweep).

Fill a tuning store from a declared grid, resumably, and report the Pareto
front over (wall time, accuracy, index bytes)::

  PYTHONPATH=src python -m benchmarks.sweep \\
      --config benchmarks/sweep_ci.toml --store sweep-store.json --report

Run it twice against the same store and the second run performs zero
probes — that is the product: the filled store ships as a CI artifact
keyed on `--fingerprint`, and a fresh checkout that loads it autotunes
warm (`--require-warm` gates exactly that).

Exit status: 0 clean; 1 when any cell failed; 3 when `--require-warm` saw
a probe.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.engine import TuningStore, device_fingerprint_id
from repro.sweep import load_config, pareto_report, run_sweep

from .common import RESULTS_DIR, save, table

DEFAULT_REPORT = os.path.join(RESULTS_DIR, "sweep_pareto.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--config", default=None,
                    help="sweep grid, TOML or JSON (see repro.sweep.config)")
    ap.add_argument("--store", default=None,
                    help="tuning store to fill (opened with nnz_tol=0: "
                         "nnz-band cells are distinct design points)")
    ap.add_argument("--resume", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="skip cells the store already holds (default); "
                         "--no-resume forgets and re-measures every cell")
    ap.add_argument("--report", nargs="?", const=DEFAULT_REPORT, default=None,
                    metavar="PATH",
                    help="write the Pareto-front JSON (every point carries "
                         f"time, rel-error, index bytes, peak-fraction); "
                         f"default path {DEFAULT_REPORT}")
    ap.add_argument("--require-warm", action="store_true",
                    help="exit 3 if any probe ran — the fresh-checkout "
                         "warm-hit gate for a shipped store artifact")
    ap.add_argument("--max-cells", type=int, default=None,
                    help="execute at most this many cells this run "
                         "(resume skips don't count); the rest defer")
    ap.add_argument("--fingerprint", action="store_true",
                    help="print this host's device fingerprint id and exit "
                         "(CI keys the store artifact on it)")
    args = ap.parse_args(argv)

    if args.fingerprint:
        print(device_fingerprint_id())
        return 0
    if not args.config or not args.store:
        ap.error("--config and --store are required (unless --fingerprint)")

    cfg = load_config(args.config)
    store = TuningStore(args.store, nnz_tol=0.0)
    result = run_sweep(cfg, store, resume=args.resume,
                       max_cells=args.max_cells, log=print)

    rows = [o.to_json() for o in result.outcomes]
    for r in rows:
        r["winners"] = " ".join(f"m{m}={n}"
                                for m, n in sorted(r["winners"].items()))
        r["seconds"] = f"{r['seconds']:.2f}"
    print()
    print(table(rows, ["cell", "status", "n_probes", "seconds", "winners"]))
    payload = result.to_json()
    payload["resume"] = args.resume
    path = save("sweep", payload)
    print(f"\nwrote {path}")
    print(f"store {store.path}: {len(store)} entries, "
          f"device {device_fingerprint_id()}, "
          f"{result.n_probes} probes this run")

    if args.report:
        rep = pareto_report(store)
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(rep, f, indent=1, default=float)
        print(f"wrote {args.report}: {rep['n_points']} points, "
              f"{rep['n_pareto']} on the Pareto front")
        front = [{
            "cell": p["cell"].split("/", 1)[1],  # drop the device id prefix
            "candidate": p["candidate"],
            "time_ms": f"{p['time_s'] * 1e3:.2f}",
            "rel_error": f"{p['rel_error']:.2e}",
            "index_kib": f"{p['index_bytes'] / 1024:.1f}",
            "peak": f"{p['peak_fraction']:.1%}",
        } for p in rep["front"]]
        print(table(front, ["cell", "candidate", "time_ms", "rel_error",
                            "index_kib", "peak"]))

    if result.count("failed"):
        print(f"{result.count('failed')} cell(s) failed", file=sys.stderr)
        return 1
    if args.require_warm and result.n_probes > 0:
        print(f"--require-warm: expected a fully warm store but "
              f"{result.n_probes} probes ran", file=sys.stderr)
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
