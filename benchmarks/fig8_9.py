"""Paper Figs. 8+9 — heterogeneous split: execution time vs workload
distribution, and speedups over the ALTO baseline across decomposition ranks.

TPU adaptation (DESIGN.md §2): the dense/MXU path plays PIM (takes the
densest chunks that "fit"), the sparse gather path plays the CPU.  We sweep
the dense workload fraction like the paper sweeps the PIM fraction, and
report rank-10 vs higher-rank speedups (paper: speedups grow with rank
because rank partitioning is replication-free).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import init_factors, table1_tensor
from repro.engine import PlanCache, build_engine

from .common import save, table, timeit

RANKS = [10, 32]
# dense fractions beyond ~0.25 densify hyper-sparse chunks — the cost model
# (split_tasks default) never chooses that region; sweeping it just burns
# minutes of einsum on mostly-zero blocks, so the sweep stops at 0.25.
FRACTIONS = [0.0, 0.1, 0.25]


def run(fast: bool = False):
    rows = []
    tensors = ["nell1", "amazon", "5d_large"] if not fast else ["amazon"]
    ranks = [10] if fast else RANKS
    for tname in tensors:
        st = table1_tensor(tname, nnz=6000 if fast else 12000)
        for rank in ranks:
            factors = [jnp.asarray(f) for f in init_factors(st.shape, rank, 0)]
            plans = PlanCache()  # the fraction sweep shares one chunking
            base = build_engine(st, "alto", rank)
            t_alto = sum(timeit(base, factors, m, warmup=1, iters=1)
                         for m in range(st.ndim))
            best = None
            for frac in FRACTIONS:
                eng = build_engine(st, "hetero", rank, mem_bytes=64 * 1024,
                                   dense_fraction=frac, plans=plans)
                t = sum(timeit(eng, factors, m, warmup=1, iters=1)
                        for m in range(st.ndim))
                rows.append(dict(
                    tensor=tname, rank=rank, dense_fraction=frac,
                    time_ms=round(t * 1e3, 2),
                    speedup_vs_alto=round(t_alto / t, 3),
                ))
                if best is None or t < best[1]:
                    best = (frac, t)
                print(f"[fig8_9] {tname} R={rank} frac={frac}: "
                      f"{rows[-1]['time_ms']}ms "
                      f"speedup={rows[-1]['speedup_vs_alto']}", flush=True)
            print(f"[fig8_9] {tname} R={rank}: best dense fraction "
                  f"{best[0]} ({best[1]*1e3:.1f} ms vs alto "
                  f"{t_alto*1e3:.1f} ms)")
    print("\n== Figs. 8/9: heterogeneous split sweep + speedups ==")
    print(table(rows, ["tensor", "rank", "dense_fraction", "time_ms",
                       "speedup_vs_alto"]))
    save("fig8_9", rows)
    return rows


if __name__ == "__main__":
    run()
