"""Paper Fig. 7 — spMTTKRP execution time + peak-performance-fraction across
implementations, all modes, rank 10.

Device roles on this host (DESIGN.md §2): the PRISM chunked engine plays
UPMEM PIM; the ALTO linearized format plays the CPU baseline; CSF fiber
trees play the tree-compressed CPU layout; plain COO scatter plays the GPU
(BLCO) baseline.  Peak-performance fraction is
useful-FLOPs / (wall × host peak), mirroring the paper's efficiency metric —
the structural (dry-run) roofline fraction for the TPU target lives in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp

from repro.core import init_factors, table1_tensor
from repro.engine import (
    CalibratedPrior,
    CalibrationError,
    PlanCache,
    TunePolicy,
    TuningStore,
    build_engine,
    default_prior,
    ranking_accuracy,
)

from .common import save, table, timeit

RANK = 10
# crude single-core peak estimate for the fraction metric (FMA @ ~3 GHz AVX2)
HOST_PEAK_FLOPS = 48e9


def mttkrp_flops(st, rank: int) -> float:
    # per nonzero: (N-1) hadamard mults + 1 value mult + 1 add, × rank
    return st.nnz * rank * (st.ndim + 1.0)


def prior_eval(tstore: TuningStore, tensors: list[str], fast: bool) -> dict:
    """Calibrated-vs-default prior scorecard over the store the suite just
    populated, plus a store-less elided cold start per tensor (the
    calibrated prior decides most modes from one anchor probe; only
    boundary candidates re-probe).  CI gates on this: elision must probe
    fewer than len(candidates) x ndim times without picking a clearly worse
    backend, and the calibrated prior's top-1 agreement with the measured
    winners must be at least the analytic default's."""
    try:
        calib = CalibratedPrior.from_store(tstore)
    except CalibrationError as e:
        print(f"[fig7] prior calibration unavailable: {e}")
        # Always (over)write the scorecard: the CI gate must see *this*
        # run's outcome, not a stale passing payload from a previous run.
        save("fig7_prior", {})
        return {}
    for line in calib.calibration.summary().splitlines():
        print(f"[fig7] {line}")
    calib_hits, decisions = ranking_accuracy(tstore, calib)
    default_hits, _ = ranking_accuracy(tstore, default_prior)
    rows = []
    for tname in tensors:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        # Two store-less cold starts back to back: a full probe sweep as the
        # live baseline (complete timings for every candidate on every
        # mode), then the elided run under the calibrated prior.  Judging
        # against the *live* sweep rather than the store keeps every elided
        # decision verifiable and minimizes clock drift between the two.
        plans = PlanCache()
        full = build_engine(st, "auto", RANK, mem_bytes=256 * 1024,
                            plans=plans,
                            tune=TunePolicy(prior="default", elide=False))
        # elide=True with a fixed moderate margin: this is the elision
        # *demonstration*, and must exercise the mechanism even when the
        # residual-derived production margin saturates at 2.0 (on these
        # micro-tensors that keeps every candidate inside the boundary and
        # elides nothing) or the model-selection guard kept analytic
        # coefficients (used_fit=False turns the default policy off).
        eng = build_engine(st, "auto", RANK, mem_bytes=256 * 1024,
                           plans=plans,
                           tune=TunePolicy(prior=calib, elide=True,
                                           elide_margin=1.35))
        rep = eng.report
        agree = ok = 0
        for mode, fwin in full.report.winners.items():
            picked = rep.winners.get(mode)
            agree += picked == fwin
            # The gate protects against elision *deciding without measuring*
            # and being clearly wrong: a pick is ok when it matches, or when
            # the full sweep's own timings put it within 2x of its winner
            # (near-tied backends flip on timing noise; the sweep would
            # have flipped too).
            per = {b: t[mode] for b, t in full.report.timings.items()
                   if mode in t}
            ok += (picked == fwin
                   or (picked in per and per[picked] <= 2.0 * per[fwin]))
        rows.append(dict(
            tensor=tname, prior=rep.prior_name,
            probes_full=full.report.n_probes, probes_elided=rep.n_probes,
            n_elided=rep.n_elided,
            winners_agree=f"{agree}/{st.ndim}",
            winners_ok=ok == st.ndim,
        ))
        print(f"[fig7] {tname} elided cold start: {rep.n_probes} probes vs "
              f"{full.report.n_probes} full sweep, winners agree "
              f"{agree}/{st.ndim}", flush=True)
    payload = dict(
        accuracy=dict(calibrated=calib_hits, default=default_hits,
                      decisions=decisions),
        residual=dict(mean_rel_err=calib.calibration.mean_rel_err,
                      max_rel_err=calib.calibration.max_rel_err,
                      n_observations=calib.calibration.n_observations),
        fitted=calib.calibration.fitted,
        # Coefficients kept at their analytic default (incl. the guard's
        # whole-fit rejection) — without this a rejected fit reads as fitted.
        fallbacks=list(calib.calibration.fallbacks),
        tensors=rows,
    )
    print(f"\n== Fig. 7 prior scorecard: calibrated top-1 "
          f"{calib_hits}/{decisions} vs default {default_hits}/{decisions} ==")
    print(table(rows, ["tensor", "prior", "probes_full", "probes_elided",
                       "n_elided", "winners_agree", "winners_ok"]))
    save("fig7_prior", payload)
    return payload


def run(fast: bool = False, store: str | TuningStore | None = None):
    """`store` — autotune persistence path shared with benchmarks.run (None
    → an ephemeral per-invocation store: benchmark numbers must never
    depend on hidden machine state, so the user-global cache is only used
    when explicitly passed).  Each tensor's "auto" engine is built twice
    against that store so the suite reports cold-vs-warm tuning overhead;
    across two invocations with the same store path the first build is
    already warm (CI gates on this)."""
    if isinstance(store, TuningStore):
        tstore = store
    elif store is None:
        import tempfile
        tstore = TuningStore(os.path.join(
            tempfile.mkdtemp(prefix="repro-fig7-"), "autotune.json"))
    else:
        tstore = TuningStore(store)
    rows = []
    tensors = ["nell2", "nell1", "amazon", "delicious", "lbnl", "5d_large"]
    if fast:
        tensors = ["nell2", "delicious"]
    engines = [("prism-chunked", "chunked"), ("prism-fixed", "fixed"),
               ("alto-cpu", "alto"), ("csf-fiber", "csf"),
               ("coo-gpu-style", "ref"), ("autotuned", "auto")]
    for tname in tensors:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        factors = [jnp.asarray(f) for f in init_factors(st.shape, RANK, 0)]
        flops = mttkrp_flops(st, RANK)
        # One plan cache per tensor: every engine (and the autotuner's
        # probes) shares a single chunking, as in a real CP-ALS run.
        plans = PlanCache()
        for ename, engine in engines:
            extra = {}
            if engine == "auto":
                t0 = time.perf_counter()
                eng = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                   plans=plans, tune=TunePolicy(store=tstore))
                tune_s = time.perf_counter() - t0
                # Re-build against the now-warm store: the fingerprint hit
                # must skip every probe, so warm tuning overhead ≈ build.
                t0 = time.perf_counter()
                warm = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                    plans=plans, tune=TunePolicy(store=tstore))
                warm_s = time.perf_counter() - t0
                extra = dict(
                    tune_ms=round(tune_s * 1e3, 2),
                    tune_probes=eng.report.n_probes,
                    tune_source=eng.report.source,
                    tune_warm_ms=round(warm_s * 1e3, 2),
                    tune_warm_probes=warm.report.n_probes,
                )
                print(f"[fig7] {tname} tuning: {eng.report.source} "
                      f"probes={eng.report.n_probes} ({extra['tune_ms']}ms) "
                      f"→ warm probes={warm.report.n_probes} "
                      f"({extra['tune_warm_ms']}ms)", flush=True)
            else:
                eng = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                   plans=plans)
            per_mode = []
            for mode in range(st.ndim):
                t = timeit(eng, factors, mode, warmup=1,
                           iters=2 if fast else 3)
                per_mode.append(t)
            total = sum(per_mode)
            frac = flops * st.ndim / (total * HOST_PEAK_FLOPS)
            label = eng.name if engine == "auto" else ename
            rows.append(dict(
                tensor=tname, engine=label,
                time_all_modes_ms=round(total * 1e3, 2),
                peak_fraction=f"{frac:.2e}",
                **extra,
            ))
            print(f"[fig7] {tname} {label}: {rows[-1]['time_all_modes_ms']}ms",
                  flush=True)
    print("\n== Fig. 7: spMTTKRP time + peak-performance fraction ==")
    print(table(rows, ["tensor", "engine", "time_all_modes_ms",
                       "peak_fraction", "tune_ms", "tune_warm_ms"]))
    save("fig7", rows)
    # The store now holds this run's measurements: score the calibrated
    # prior against them and demonstrate cross-mode elision per tensor.
    prior_eval(tstore, tensors, fast)
    return rows


if __name__ == "__main__":
    run()
