"""Paper Fig. 7 — spMTTKRP execution time + peak-performance-fraction across
implementations, all modes, rank 10.

Device roles on this host (DESIGN.md §2): the PRISM chunked engine plays
UPMEM PIM; ALTO-ordered segment-sum plays the CPU baseline; plain COO
scatter plays the GPU (BLCO) baseline.  Peak-performance fraction is
useful-FLOPs / (wall × host peak), mirroring the paper's efficiency metric —
the structural (dry-run) roofline fraction for the TPU target lives in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TABLE1, init_factors, table1_tensor
from repro.engine import PlanCache, build_engine

from .common import save, table, timeit

RANK = 10
# crude single-core peak estimate for the fraction metric (FMA @ ~3 GHz AVX2)
HOST_PEAK_FLOPS = 48e9


def mttkrp_flops(st, rank: int) -> float:
    # per nonzero: (N-1) hadamard mults + 1 value mult + 1 add, × rank
    return st.nnz * rank * (st.ndim + 1.0)


def run(fast: bool = False):
    rows = []
    tensors = ["nell2", "nell1", "amazon", "delicious", "lbnl", "5d_large"]
    if fast:
        tensors = ["nell2", "delicious"]
    engines = [("prism-chunked", "chunked"), ("prism-fixed", "fixed"),
               ("alto-cpu", "alto"), ("coo-gpu-style", "ref"),
               ("autotuned", "auto")]
    for tname in tensors:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        factors = [jnp.asarray(f) for f in init_factors(st.shape, RANK, 0)]
        flops = mttkrp_flops(st, RANK)
        # One plan cache per tensor: every engine (and the autotuner's
        # probes) shares a single chunking, as in a real CP-ALS run.
        plans = PlanCache()
        for ename, engine in engines:
            eng = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                               plans=plans)
            per_mode = []
            for mode in range(st.ndim):
                t = timeit(eng, factors, mode, warmup=1,
                           iters=2 if fast else 3)
                per_mode.append(t)
            total = sum(per_mode)
            frac = flops * st.ndim / (total * HOST_PEAK_FLOPS)
            label = eng.name if engine == "auto" else ename
            rows.append(dict(
                tensor=tname, engine=label,
                time_all_modes_ms=round(total * 1e3, 2),
                peak_fraction=f"{frac:.2e}",
            ))
            print(f"[fig7] {tname} {label}: {rows[-1]['time_all_modes_ms']}ms",
                  flush=True)
    print("\n== Fig. 7: spMTTKRP time + peak-performance fraction ==")
    print(table(rows, ["tensor", "engine", "time_all_modes_ms",
                       "peak_fraction"]))
    save("fig7", rows)
    return rows


if __name__ == "__main__":
    run()
