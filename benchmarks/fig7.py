"""Paper Fig. 7 — spMTTKRP execution time + peak-performance-fraction across
implementations, all modes, rank 10.

Device roles on this host (DESIGN.md §2): the PRISM chunked engine plays
UPMEM PIM; ALTO-ordered segment-sum plays the CPU baseline; plain COO
scatter plays the GPU (BLCO) baseline.  Peak-performance fraction is
useful-FLOPs / (wall × host peak), mirroring the paper's efficiency metric —
the structural (dry-run) roofline fraction for the TPU target lives in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp

from repro.core import init_factors, table1_tensor
from repro.engine import PlanCache, TuningStore, build_engine

from .common import save, table, timeit

RANK = 10
# crude single-core peak estimate for the fraction metric (FMA @ ~3 GHz AVX2)
HOST_PEAK_FLOPS = 48e9


def mttkrp_flops(st, rank: int) -> float:
    # per nonzero: (N-1) hadamard mults + 1 value mult + 1 add, × rank
    return st.nnz * rank * (st.ndim + 1.0)


def run(fast: bool = False, store: str | TuningStore | None = None):
    """`store` — autotune persistence path shared with benchmarks.run (None
    → an ephemeral per-invocation store: benchmark numbers must never
    depend on hidden machine state, so the user-global cache is only used
    when explicitly passed).  Each tensor's "auto" engine is built twice
    against that store so the suite reports cold-vs-warm tuning overhead;
    across two invocations with the same store path the first build is
    already warm (CI gates on this)."""
    if isinstance(store, TuningStore):
        tstore = store
    elif store is None:
        import tempfile
        tstore = TuningStore(os.path.join(
            tempfile.mkdtemp(prefix="repro-fig7-"), "autotune.json"))
    else:
        tstore = TuningStore(store)
    rows = []
    tensors = ["nell2", "nell1", "amazon", "delicious", "lbnl", "5d_large"]
    if fast:
        tensors = ["nell2", "delicious"]
    engines = [("prism-chunked", "chunked"), ("prism-fixed", "fixed"),
               ("alto-cpu", "alto"), ("coo-gpu-style", "ref"),
               ("autotuned", "auto")]
    for tname in tensors:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        factors = [jnp.asarray(f) for f in init_factors(st.shape, RANK, 0)]
        flops = mttkrp_flops(st, RANK)
        # One plan cache per tensor: every engine (and the autotuner's
        # probes) shares a single chunking, as in a real CP-ALS run.
        plans = PlanCache()
        for ename, engine in engines:
            extra = {}
            if engine == "auto":
                t0 = time.perf_counter()
                eng = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                   plans=plans, store=tstore)
                tune_s = time.perf_counter() - t0
                # Re-build against the now-warm store: the fingerprint hit
                # must skip every probe, so warm tuning overhead ≈ build.
                t0 = time.perf_counter()
                warm = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                    plans=plans, store=tstore)
                warm_s = time.perf_counter() - t0
                extra = dict(
                    tune_ms=round(tune_s * 1e3, 2),
                    tune_probes=eng.report.n_probes,
                    tune_source=eng.report.source,
                    tune_warm_ms=round(warm_s * 1e3, 2),
                    tune_warm_probes=warm.report.n_probes,
                )
                print(f"[fig7] {tname} tuning: {eng.report.source} "
                      f"probes={eng.report.n_probes} ({extra['tune_ms']}ms) "
                      f"→ warm probes={warm.report.n_probes} "
                      f"({extra['tune_warm_ms']}ms)", flush=True)
            else:
                eng = build_engine(st, engine, RANK, mem_bytes=256 * 1024,
                                   plans=plans)
            per_mode = []
            for mode in range(st.ndim):
                t = timeit(eng, factors, mode, warmup=1,
                           iters=2 if fast else 3)
                per_mode.append(t)
            total = sum(per_mode)
            frac = flops * st.ndim / (total * HOST_PEAK_FLOPS)
            label = eng.name if engine == "auto" else ename
            rows.append(dict(
                tensor=tname, engine=label,
                time_all_modes_ms=round(total * 1e3, 2),
                peak_fraction=f"{frac:.2e}",
                **extra,
            ))
            print(f"[fig7] {tname} {label}: {rows[-1]['time_all_modes_ms']}ms",
                  flush=True)
    print("\n== Fig. 7: spMTTKRP time + peak-performance fraction ==")
    print(table(rows, ["tensor", "engine", "time_all_modes_ms",
                       "peak_fraction", "tune_ms", "tune_warm_ms"]))
    save("fig7", rows)
    return rows


if __name__ == "__main__":
    run()
