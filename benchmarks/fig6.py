"""Paper Fig. 6 — number formats × lock usage: convergence (average absolute
difference) and per-mode execution time, on Nell-2-like (mode-3),
Delicious-like (mode-4) and LBNL-like (mode-5) tensors.

Formats: Float (f32), Int7 (Q9.7/16-bit), Int15-12 (Q17.15 + prec_shift 3).
Locks: exact scatter ("locks") vs wave-collision-drop emulation ("no locks",
DESIGN.md §2.1).  Expected reproduction of the paper's findings:
  * fixed-point convergence within a fraction of a % of float;
  * Int7 slightly worse than Int15-12 on mode-4/5 tensors;
  * lock removal does not meaningfully change convergence.
"""
from __future__ import annotations

import time

from repro.core import cp_als, table1_tensor
from repro.engine import PlanCache, TunePolicy, candidate_lossless

from .common import save, table

TENSORS = ["nell2", "delicious", "lbnl"]
FORMATS = [("float", "chunked", None), ("int7", "fixed", "int7"),
           ("int15-12", "fixed", "int15-12")]
RANK = 10
ITERS = 5
#: The Fig.-6 format study as an autotune candidate space: the chunked
#: execution strategy in float against every fixed-point preset of the same
#: strategy — the paper's accuracy-vs-speed question, decided empirically
#: per workload under an explicit error budget.
TUNE_CANDIDATES = ["chunked", "fixed:int3", "fixed:int7", "fixed:int15-12"]


def _tune_rows(iters: int, fast: bool, accuracy_budget: float | None):
    """Accuracy-budgeted format autotuning over the fig6 workloads.

    Two passes per tensor: `budget=None` (the regression guard — default
    candidates, so no lossy backend may ever win) and, when an
    `--accuracy-budget` was given, a budgeted pass over `TUNE_CANDIDATES`
    where each fixed-point preset competes under its measured error.  The
    CI `format-autotune` job gates on these rows."""
    rows = []
    budgets = [None] if accuracy_budget is None else [None, accuracy_budget]
    for tname in TENSORS:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        plans = PlanCache()
        for budget in budgets:
            tune = (TunePolicy() if budget is None else
                    TunePolicy(accuracy_budget=budget,
                               candidates=tuple(TUNE_CANDIDATES)))
            res = cp_als(st, RANK, n_iters=iters, engine="auto", seed=0,
                         mem_bytes=256 * 1024, plans=plans, tune=tune)
            rep = res.tune_report
            picked = {str(m): w for m, w in sorted(rep.winners.items())}
            lossy_picks = sorted({w for w in rep.winners.values()
                                  if not candidate_lossless(w)})
            winner_err = max(
                (e for w in lossy_picks
                 for e in rep.errors.get(w, {}).values()), default=None)
            rows.append(dict(
                tensor=tname, fmt="autotune",
                budget=budget,
                engine=res.engine,
                picked=picked,
                lossy_picks=lossy_picks,
                winner_max_error=winner_err,
                within_budget=(winner_err is None
                               or (budget is not None and winner_err <= budget)),
                errors={c: round(max(per.values()), 6)
                        for c, per in rep.errors.items()},
                rejected={c: why for c, why in rep.skipped.items()
                          if "accuracy budget" in why},
                candidates=list(rep.candidates),
                avg_abs_diff=round(res.diff_history[-1], 6),
                fit=round(res.fit_history[-1], 4),
                quant_error=res.quant_error,
            ))
            print(f"[fig6] {tname} autotune budget={budget}: {res.engine} "
                  f"lossy_picks={lossy_picks or '-'} "
                  f"winner_err={winner_err}", flush=True)
    return rows


def run(fast: bool = False, accuracy_budget: float | None = None):
    rows = []
    iters = 2 if fast else ITERS
    for tname in TENSORS:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        plans = PlanCache()  # all formats × lock modes share one chunking
        for fmt_name, engine, preset in FORMATS:
            for locks in (True, False):
                kw = dict(engine=engine, seed=0, mem_bytes=256 * 1024,
                          lockfree_mode=not locks, plans=plans)
                if preset:
                    kw["fixed_preset"] = preset
                t0 = time.perf_counter()
                res = cp_als(st, RANK, n_iters=iters, **kw)
                wall = time.perf_counter() - t0
                rows.append(dict(
                    tensor=tname, fmt=fmt_name,
                    locks="locks" if locks else "no-locks",
                    avg_abs_diff=round(res.diff_history[-1], 6),
                    fit=round(res.fit_history[-1], 4),
                    time_per_iter_s=round(sum(res.iter_times) / iters, 3),
                    total_s=round(wall, 2),
                ))
                print(f"[fig6] {tname} {fmt_name} "
                      f"{'locks' if locks else 'no-locks'}: "
                      f"diff={rows[-1]['avg_abs_diff']} "
                      f"t/iter={rows[-1]['time_per_iter_s']}s", flush=True)
    print("\n== Fig. 6: formats × locks — convergence and time ==")
    print(table(rows, ["tensor", "fmt", "locks", "avg_abs_diff", "fit",
                       "time_per_iter_s"]))
    # Paper-claim checks (soft, printed).  The paper's recommendation:
    # Int7 for mode-3 tensors, Int15-12 for mode-4/5 ("This suggests
    # Int15-12 as the preferred format for mode-4 and mode-5 tensors").
    by = {(r["tensor"], r["fmt"], r["locks"]): r for r in rows}
    modes = {"nell2": 3, "delicious": 4, "lbnl": 5}
    for tname in TENSORS:
        f = by[(tname, "float", "locks")]["avg_abs_diff"]
        rec_fmt = "int7" if modes.get(tname, 3) == 3 else "int15-12"
        for fmt in ("int7", "int15-12"):
            q = by[(tname, fmt, "locks")]["avg_abs_diff"]
            rel = abs(q - f) / max(abs(f), 1e-12)
            mark = ""
            if fmt == rec_fmt:
                mark = (" [recommended fmt] "
                        + ("OK" if rel < 0.05 else "DIVERGES"))
            print(f"[claim] {tname} (mode-{modes.get(tname, 3)}): "
                  f"|{fmt} - float| rel diff = {rel:.3%}{mark}")

    # Accuracy-budgeted format autotuning: the same trade-off, decided by
    # the tuner under an explicit error budget (CI gates on these rows).
    tune = _tune_rows(iters, fast, accuracy_budget)
    rows.extend(tune)
    print("\n== Fig. 6: accuracy-budgeted format autotuning ==")
    print(table(tune, ["tensor", "budget", "engine", "lossy_picks",
                       "winner_max_error", "within_budget", "fit"]))
    save("fig6", rows)
    return rows


if __name__ == "__main__":
    run()
