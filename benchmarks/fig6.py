"""Paper Fig. 6 — number formats × lock usage: convergence (average absolute
difference) and per-mode execution time, on Nell-2-like (mode-3),
Delicious-like (mode-4) and LBNL-like (mode-5) tensors.

Formats: Float (f32), Int7 (Q9.7/16-bit), Int15-12 (Q17.15 + prec_shift 3).
Locks: exact scatter ("locks") vs wave-collision-drop emulation ("no locks",
DESIGN.md §2.1).  Expected reproduction of the paper's findings:
  * fixed-point convergence within a fraction of a % of float;
  * Int7 slightly worse than Int15-12 on mode-4/5 tensors;
  * lock removal does not meaningfully change convergence.
"""
from __future__ import annotations

import time

from repro.core import avg_abs_diff, cp_als, table1_tensor
from repro.engine import PlanCache

from .common import save, table

TENSORS = ["nell2", "delicious", "lbnl"]
FORMATS = [("float", "chunked", None), ("int7", "fixed", "int7"),
           ("int15-12", "fixed", "int15-12")]
RANK = 10
ITERS = 5


def run(fast: bool = False):
    rows = []
    iters = 2 if fast else ITERS
    for tname in TENSORS:
        st = table1_tensor(tname, nnz=8000 if fast else None)
        plans = PlanCache()  # all formats × lock modes share one chunking
        for fmt_name, engine, preset in FORMATS:
            for locks in (True, False):
                kw = dict(engine=engine, seed=0, mem_bytes=256 * 1024,
                          lockfree_mode=not locks, plans=plans)
                if preset:
                    kw["fixed_preset"] = preset
                t0 = time.perf_counter()
                res = cp_als(st, RANK, n_iters=iters, **kw)
                wall = time.perf_counter() - t0
                rows.append(dict(
                    tensor=tname, fmt=fmt_name,
                    locks="locks" if locks else "no-locks",
                    avg_abs_diff=round(res.diff_history[-1], 6),
                    fit=round(res.fit_history[-1], 4),
                    time_per_iter_s=round(sum(res.iter_times) / iters, 3),
                    total_s=round(wall, 2),
                ))
                print(f"[fig6] {tname} {fmt_name} "
                      f"{'locks' if locks else 'no-locks'}: "
                      f"diff={rows[-1]['avg_abs_diff']} "
                      f"t/iter={rows[-1]['time_per_iter_s']}s", flush=True)
    print("\n== Fig. 6: formats × locks — convergence and time ==")
    print(table(rows, ["tensor", "fmt", "locks", "avg_abs_diff", "fit",
                       "time_per_iter_s"]))
    # Paper-claim checks (soft, printed).  The paper's recommendation:
    # Int7 for mode-3 tensors, Int15-12 for mode-4/5 ("This suggests
    # Int15-12 as the preferred format for mode-4 and mode-5 tensors").
    by = {(r["tensor"], r["fmt"], r["locks"]): r for r in rows}
    modes = {"nell2": 3, "delicious": 4, "lbnl": 5}
    for tname in TENSORS:
        f = by[(tname, "float", "locks")]["avg_abs_diff"]
        rec_fmt = "int7" if modes.get(tname, 3) == 3 else "int15-12"
        for fmt in ("int7", "int15-12"):
            q = by[(tname, fmt, "locks")]["avg_abs_diff"]
            rel = abs(q - f) / max(abs(f), 1e-12)
            mark = ""
            if fmt == rec_fmt:
                mark = (" [recommended fmt] "
                        + ("OK" if rel < 0.05 else "DIVERGES"))
            print(f"[claim] {tname} (mode-{modes.get(tname, 3)}): "
                  f"|{fmt} - float| rel diff = {rel:.3%}{mark}")
    save("fig6", rows)
    return rows


if __name__ == "__main__":
    run()
