"""Serving benchmark: batched decomposition service vs a sequential loop.

Drives synthetic concurrent load — many small sparse tensors across a few
(shape class, nnz band) buckets — through three paths:

  sequential — `cp_als` one tensor at a time (the pre-batching baseline);
  batched    — one `cp_als_batched` call over the whole set;
  service    — `DecomposeService` under concurrent client threads, with
               request coalescing, measuring per-request latency.

Reports throughput (tensors/s), p50/p99 request latency for the service
path, per-path probe counts, and per-tensor factor parity between the
batched and sequential paths (gated at 1e-5).  JSON lands in
`results/bench/serve_bench.json`; CI's `serve-smoke` job runs this twice
against one store and gates on the second (warm) run reporting zero probes.

  PYTHONPATH=src python -m benchmarks.serve_bench --fast \
      --store "$TMPDIR/serve-store.json"
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.batch import BucketPlanCache, cp_als_batched
from repro.core import SparseTensor, cp_als
from repro.engine import TunePolicy
from repro.obs import (
    enable_tracing,
    get_tracer,
    read_jsonl,
    summarize_text,
    write_jsonl,
)
from repro.serve import DecomposeService

from .common import save, table

RANK = 5
N_ITERS = 3


def synthetic_load(n: int, seed: int = 0) -> list[SparseTensor]:
    """`n` small tensors drawn from three shape/nnz families, shuffled — the
    arrival order interleaves buckets the way concurrent users would."""
    rng = np.random.default_rng(seed)
    families = [
        ((12, 10, 8), (40, 70)),     # 3-D, band 5/6
        ((16, 16, 16), (90, 120)),   # pow-2 dims, band 6
        ((24, 24), (50, 60)),        # 2-D, band 5
    ]
    tensors = []
    for i in range(n):
        shape, (lo, hi) = families[i % len(families)]
        nnz = int(rng.integers(lo, hi))
        coords = np.stack([rng.integers(0, d, size=nnz) for d in shape],
                          axis=1).astype(np.int32)
        values = rng.uniform(-1, 1, size=nnz).astype(np.float32)
        tensors.append(SparseTensor(coords, values, shape))
    order = rng.permutation(n)
    return [tensors[i] for i in order]


def _probes(results) -> int:
    """Total autotune probes across the unique bucket reports."""
    reports = {id(r.tune_report): r.tune_report
               for r in results if r.tune_report is not None}
    return sum(rep.n_probes for rep in reports.values())


def run_sequential(tensors, tune: TunePolicy):
    t0 = time.perf_counter()
    results = [cp_als(t, RANK, n_iters=N_ITERS, engine="ref",
                      track_diff=False) for t in tensors]
    wall = time.perf_counter() - t0
    return results, dict(path="sequential", wall_s=wall,
                         throughput=len(tensors) / wall, n_probes=0)


def run_batched(tensors, tune: TunePolicy):
    # Warm-up on a tiny disjoint load first so the row measures steady-state
    # dispatch, not one-time jit compilation of the batched kernels.
    t0 = time.perf_counter()
    results = cp_als_batched(tensors, RANK, n_iters=N_ITERS, tune=tune,
                             plans=BucketPlanCache())
    wall = time.perf_counter() - t0
    return results, dict(path="batched", wall_s=wall,
                         throughput=len(tensors) / wall,
                         n_probes=_probes(results))


def run_service(tensors, tune: TunePolicy, *, max_batch: int,
                max_wait_ms: float, clients: int):
    """Concurrent load: `clients` threads each submit a slice of the
    tensors and wait; per-request latency is submit→result."""
    latencies = [0.0] * len(tensors)
    with DecomposeService(RANK, N_ITERS, tune=tune, max_batch=max_batch,
                          max_wait_ms=max_wait_ms) as svc:
        t0 = time.perf_counter()

        def client(idxs):
            for i in idxs:
                ts = time.perf_counter()
                svc.decompose(tensors[i], timeout=600)
                latencies[i] = time.perf_counter() - ts

        threads = [threading.Thread(target=client,
                                    args=(range(c, len(tensors), clients),))
                   for c in range(clients)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        stats = svc.stats()
    lat = np.asarray(latencies)
    return dict(path="service", wall_s=wall,
                throughput=len(tensors) / wall,
                p50_ms=float(np.percentile(lat, 50) * 1e3),
                p99_ms=float(np.percentile(lat, 99) * 1e3),
                n_probes=stats.n_probes,
                n_batches=stats.n_batches,
                n_buckets=stats.n_buckets,
                max_batch_seen=stats.max_batch_seen,
                bucket_decisions=stats.n_bucket_decisions,
                # Service-side histogram estimates (submit→dispatch and
                # submit→result), alongside the client-measured percentiles.
                svc_queue_wait_ms=stats.queue_wait_ms,
                svc_request_ms=stats.request_ms)


def parity(batched, sequential) -> float:
    worst = 0.0
    for rb, rs in zip(batched, sequential, strict=True):
        for fb, fs in zip(rb.factors, rs.factors, strict=True):
            worst = max(worst, float(np.max(np.abs(fb - np.asarray(fs)))))
        worst = max(worst, float(np.max(np.abs(rb.lam - np.asarray(rs.lam)))))
    return worst


def matched_sequential(tensors, batched_results):
    """Per-tensor sequential `cp_als` runs using the SAME kernel the batched
    path picked for that tensor's bucket — the parity gate compares
    like-for-like (the batched kernels are vmapped versions of the
    sequential ones, bit-exact member-wise; comparing a batched-ALTO result
    against sequential-COO would only measure ALTO's different summation
    order, which the sequential path exhibits identically)."""
    from repro.engine import build_engine
    out = []
    for t, rb in zip(tensors, batched_results, strict=True):
        names = {w.removeprefix("batched:")
                 for w in rb.tune_report.winners.values()}
        if len(names) == 1:
            engine = names.pop()
        else:  # per-mode mixed winners: route each mode to its kernel
            per_mode = {m: build_engine(t, w.removeprefix("batched:"), RANK)
                        for m, w in rb.tune_report.winners.items()}
            def engine(factors, mode, _e=per_mode):
                return _e[mode](factors, mode)
        out.append(cp_als(t, RANK, n_iters=N_ITERS, engine=engine,
                          track_diff=False))
    return out


def run(n: int, *, store, max_batch: int, max_wait_ms: float, clients: int,
        seed: int = 0):
    tune = TunePolicy(store=store)
    tensors = synthetic_load(n, seed=seed)
    # One throwaway batched pass over a tiny prefix compiles the vmap'd
    # kernels so neither timed path pays one-time jit cost.
    cp_als_batched(tensors[: min(3, n)], RANK, n_iters=1)

    seq_results, seq_row = run_sequential(tensors, tune)
    bat_results, bat_row = run_batched(tensors, tune)
    svc_row = run_service(tensors, tune, max_batch=max_batch,
                          max_wait_ms=max_wait_ms, clients=clients)

    worst = parity(bat_results, matched_sequential(tensors, bat_results))
    bat_row["parity_max_abs"] = worst
    rows = [seq_row, bat_row, svc_row]
    bucket_reports = {id(r.tune_report): r.tune_report
                      for r in bat_results if r.tune_report is not None}
    payload = dict(
        n_tensors=n, rank=RANK, n_iters=N_ITERS,
        max_batch=max_batch, max_wait_ms=max_wait_ms, clients=clients,
        parity_max_abs=worst, parity_ok=worst <= 1e-5,
        batched_speedup=seq_row["wall_s"] / bat_row["wall_s"],
        bucket_reports=[rep.to_dict() for rep in bucket_reports.values()],
        rows=rows,
    )
    print(table([{k: (f"{v:.4g}" if isinstance(v, float) else v)
                  for k, v in r.items()}
                 for r in rows],
                ["path", "wall_s", "throughput", "p50_ms", "p99_ms",
                 "n_probes", "parity_max_abs"]))
    print(f"[serve_bench] batched speedup over sequential: "
          f"{payload['batched_speedup']:.2f}x; parity {worst:.2e} "
          f"({'OK' if payload['parity_ok'] else 'FAIL'})")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=64,
                    help="number of synthetic tensors (default 64)")
    ap.add_argument("--fast", action="store_true",
                    help="pruned load for CI (24 tensors, 2 clients)")
    ap.add_argument("--store", default=None,
                    help="TuningStore path shared across runs (warm gating)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable span tracing and write the trace JSONL "
                         "here (see docs/observability.md)")
    args = ap.parse_args(argv)
    n = 24 if args.fast else args.n
    # Closed-loop clients: each waits for its result before submitting the
    # next request, so client concurrency caps the coalesced batch size —
    # the service's throughput ceiling on this synthetic load is set by the
    # load generator, not the coalescer.
    clients = 2 if args.fast else args.clients
    if args.trace:
        enable_tracing()
    payload = run(n, store=args.store, max_batch=args.max_batch,
                  max_wait_ms=args.max_wait_ms, clients=clients,
                  seed=args.seed)
    path = save("serve_bench", payload)
    print(f"[serve_bench] wrote {path}")
    if args.trace:
        tracer = get_tracer()
        trace_path = write_jsonl(tracer.spans(), args.trace, tracer=tracer)
        print(f"[serve_bench] wrote {trace_path} ({len(tracer)} spans)")
        print(summarize_text(*read_jsonl(trace_path)))
    if not payload["parity_ok"]:
        raise SystemExit("parity gate failed")
    return payload


if __name__ == "__main__":
    main()
