"""Paper Table I — the evaluation tensors.

FROSTT isn't available offline; this benchmark materializes the synthetic
stand-ins (scaled dims, matched mode count and balance character), reports
their stats, and the partition plan the Fig. 5 decider picks for each.
"""
from __future__ import annotations

from repro.core import TABLE1, decide_partition, table1_tensor

from .common import save, table


def run():
    rows = []
    for name in TABLE1:
        st = table1_tensor(name)
        plan = decide_partition(st, rank=10, mem_bytes=256 * 1024,
                                n_devices=2560, rank_axis=10)
        rows.append(dict(
            tensor=name,
            dims="x".join(str(d) for d in st.shape),
            nnz=st.nnz,
            density=f"{st.density:.2e}",
            chunk_shape="x".join(str(c) for c in plan.chunk_shape),
            capacity=plan.capacity,
            rank_block=plan.rank_block,
            kernel_iters=plan.kernel_iterations,
        ))
    print("\n== Table I (synthetic stand-ins) + Fig.5 partition plans ==")
    print(table(rows, ["tensor", "dims", "nnz", "density", "chunk_shape",
                       "capacity", "rank_block", "kernel_iters"]))
    save("table1", rows)
    return rows


if __name__ == "__main__":
    run()
