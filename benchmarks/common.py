"""Shared benchmark utilities: timing, result tables, output dirs."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def save(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols)
        for r in rows)
    return f"{head}\n{sep}\n{body}"
