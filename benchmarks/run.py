"""Benchmark entrypoint: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig6,fig7,...]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced nnz/iters (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of suites to run; unknown "
                         "names abort before any suite runs")
    ap.add_argument("--accuracy-budget", type=float, default=None,
                    help="max per-mode MTTKRP relative error for the fig6 "
                         "format-autotuning rows: admits fixed-point preset "
                         "candidates to the tuner, each policed against "
                         "this budget (CI gates on the resulting "
                         "fig6.json rows)")
    ap.add_argument("--store", default=None,
                    help="autotune persistence store path, shared by every "
                         "suite that tunes; repeat invocations against the "
                         "same path start warm (CI gates on this).  Default: "
                         "an ephemeral per-invocation store, so benchmark "
                         "numbers never depend on hidden machine state")
    args = ap.parse_args()

    import tempfile

    from repro.engine import TuningStore

    from . import fig6, fig7, fig8_9, table1
    # One store for the whole benchmark invocation: a suite that autotunes
    # warms the next, and a repeat invocation against the same --store path
    # starts warm (reported as cold-vs-warm tuning overhead in fig7's rows;
    # CI gates on it).  Without --store the store is ephemeral — benchmarks
    # must be reproducible from the checkout alone, so they never read or
    # write the user-global cache implicitly.
    store_path = args.store or os.path.join(
        tempfile.mkdtemp(prefix="repro-bench-"), "autotune.json")
    store = TuningStore(store_path)
    suites = {
        "table1": lambda: table1.run(),
        "fig6": lambda: fig6.run(fast=args.fast,
                                 accuracy_budget=args.accuracy_budget),
        "fig7": lambda: fig7.run(fast=args.fast, store=store),
        "fig8_9": lambda: fig8_9.run(fast=args.fast),
    }
    # Validate the whole --only list before running anything: a typo'd name
    # ("fig8" for "fig8_9", a stray comma) must abort with the valid names,
    # not silently run the recognizable subset and exit 0.
    only = ([t.strip() for t in args.only.split(",")] if args.only
            else list(suites))
    unknown = sorted({repr(n) for n in only if n not in suites})
    if unknown:
        print(f"unknown benchmark suite(s): {', '.join(unknown)}; "
              f"valid names: {', '.join(sorted(suites))}", file=sys.stderr)
        sys.exit(2)
    failed = []
    for name in only:
        print(f"\n######## benchmarks.{name} ########", flush=True)
        t0 = time.time()
        try:
            suites[name]()
            print(f"######## {name} done in {time.time()-t0:.1f}s ########",
                  flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        # Non-zero exit so CI gates on benchmark health.
        print(f"benchmark suites failed: {failed}", file=sys.stderr)
        sys.exit(1)

    # What the invocation left behind for the next one: the store's entries
    # are both warm-start winners and the calibration's training data.
    obs = store.observations()
    if obs:
        from repro.engine import CalibratedPrior, CalibrationError, default_prior, ranking_accuracy
        line = (f"autotune store {store.path}: {len(store)} entries, "
                f"{len(obs)} observations")
        try:
            calib = CalibratedPrior.from_store(store)
            ch, total = ranking_accuracy(store, calib)
            dh, _ = ranking_accuracy(store, default_prior)
            line += (f"; calibrated prior rel err "
                     f"{calib.calibration.mean_rel_err:.0%}, top-1 "
                     f"{ch}/{total} (default prior {dh}/{total})")
        except CalibrationError as e:
            line += f"; calibration unavailable ({e})"
        print(line, flush=True)
    print(f"\nall benchmark suites passed: {only}", flush=True)


if __name__ == "__main__":
    main()
